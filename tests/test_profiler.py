"""Comm-runtime span profiler (repro.comm.profiler, DESIGN.md §12):
host-side unit tests of the event sink, the issue/signal/wait pairing,
and the span emission — synthetic ``LegEvent`` streams, no mesh.  The
instrumented end-to-end runs live in tests/multidevice/test_profile_e2e.py.
"""
import pytest

from repro.comm.profiler import (
    CommProfiler,
    LegEvent,
    active,
    emit_leg_spans,
    mark_compute,
    profile,
)
from repro.serving.metrics import RecordingTracker, validate_record


def _comm_meta(prof, **kw):
    base = dict(kind="comm", stream="ring", channel="ring.shift1", stage=0,
                axes=("pod", "model"), nbytes=2048, n_tensors=2,
                backend="xla", intent="ring attend")
    base.update(kw)
    return prof.new_leg(**base)


def _ev(meta, phase, coords, t):
    return LegEvent(meta, phase, coords, t)


def _fresh_tracker():
    t = RecordingTracker()
    t.epoch = 0.0  # synthetic event times below are absolute-from-zero
    return t


# ---------------------------------------------------------------------------
# sink mechanics
# ---------------------------------------------------------------------------

def test_profile_context_sets_and_restores_active():
    assert active() is None
    p = CommProfiler()
    with profile(p) as got:
        assert got is p and active() is p
        with profile(CommProfiler()) as inner:
            assert active() is inner
        assert active() is p
    assert active() is None


def test_new_leg_ids_monotone_and_record_never_raises():
    p = CommProfiler()
    a = _comm_meta(p)
    b = _comm_meta(p, channel="torus.hop1")
    assert (a.leg, b.leg) == (0, 1)
    p._record(a, "issue", [0, 1])
    p._record(a, "signal", object())  # uncoercible coords must not raise
    assert [e.coords for e in p.events] == [(0, 1), ()]


def test_take_drains_atomically():
    p = CommProfiler()
    m = _comm_meta(p)
    p._record(m, "issue", [0])
    assert len(p.take()) == 1
    assert p.take() == [] and p.events == []


def test_mark_compute_is_noop_without_active_profiler():
    # no profiler active: must not touch jax at all (host-side early out)
    mark_compute("attend", ("model",), [], [])


# ---------------------------------------------------------------------------
# pairing + span emission
# ---------------------------------------------------------------------------

def test_comm_leg_pairing_and_exposure():
    p = CommProfiler()
    m = _comm_meta(p)
    # occurrence 0: signal lands BEFORE the consumer waits (fully hidden);
    # occurrence 1: the wait beats the signal by 3ms (exposed stall)
    p.events = [
        _ev(m, "issue", (0, 1), 1.000),
        _ev(m, "signal", (0, 1), 1.010),
        _ev(m, "wait", (0, 1), 1.020),
        _ev(m, "issue", (0, 1), 2.000),
        _ev(m, "wait", (0, 1), 2.005),
        _ev(m, "signal", (0, 1), 2.008),
    ]
    t = _fresh_tracker()
    n = emit_leg_spans(p, t)
    legs = [r for r in t.records if r.name == "comm.leg"]
    stalls = [r for r in t.records if r.name == "comm.exposed_wait"]
    assert n == len(legs) + len(stalls) == 3
    assert [r.tags["occ"] for r in legs] == [0, 1]
    assert legs[0].tags["exposed_s"] == 0.0
    assert legs[0].t_start == pytest.approx(1.0)
    assert legs[0].value == pytest.approx(0.010)
    assert legs[1].tags["exposed_s"] == pytest.approx(0.003)
    (stall,) = stalls
    assert stall.t_start == pytest.approx(2.005)
    assert stall.value == pytest.approx(0.003)
    assert stall.tags["track"] == "pod=0,model=1"
    for r in t.records:
        assert validate_record(r.to_dict()) == []
    # drained: a second emit publishes nothing
    assert emit_leg_spans(p, t) == 0


def test_unsignaled_occurrence_dropped():
    """A leg whose signal never fired (crash mid-step) emits no span —
    half-pairs must not fabricate durations."""
    p = CommProfiler()
    m = _comm_meta(p)
    p.events = [_ev(m, "issue", (0, 0), 1.0),
                _ev(m, "issue", (0, 0), 2.0),
                _ev(m, "signal", (0, 0), 2.1)]
    t = _fresh_tracker()
    assert emit_leg_spans(p, t) == 1
    (leg,) = [r for r in t.records if r.name == "comm.leg"]
    assert leg.t_start == pytest.approx(2.0)


def test_per_device_timelines_are_separate():
    """The same trace-time leg on two devices pairs independently and
    lands on distinct Perfetto tracks."""
    p = CommProfiler()
    m = _comm_meta(p)
    p.events = [
        _ev(m, "issue", (0, 0), 1.00), _ev(m, "issue", (0, 1), 1.01),
        _ev(m, "signal", (0, 1), 1.02), _ev(m, "signal", (0, 0), 1.03),
    ]
    t = _fresh_tracker()
    assert emit_leg_spans(p, t) == 2
    tracks = {r.tags["track"]: r.value for r in t.records}
    assert tracks["pod=0,model=0"] == pytest.approx(0.03)
    assert tracks["pod=0,model=1"] == pytest.approx(0.01)


def test_compute_block_pairing():
    p = CommProfiler()
    m = p.new_leg(kind="compute", stream="ring", channel="ring attend",
                  stage=0, axes=("model",), nbytes=0, n_tensors=0,
                  backend="", intent="", label="ring attend")
    p.events = [_ev(m, "start", (2,), 1.0), _ev(m, "end", (2,), 1.5),
                _ev(m, "start", (2,), 2.0), _ev(m, "end", (2,), 2.25),
                _ev(m, "end", (2,), 3.0)]  # end without start: ignored
    t = _fresh_tracker()
    assert emit_leg_spans(p, t) == 2
    assert all(r.name == "comm.compute" for r in t.records)
    assert [r.value for r in t.records] == pytest.approx([0.5, 0.25])
    assert [r.tags["occ"] for r in t.records] == [0, 1]
    assert all(r.tags["label"] == "ring attend" for r in t.records)


def test_pre_epoch_events_clamp_to_zero():
    """Events recorded before the tracker's epoch (profiler outlives the
    sink) clamp to t_start=0 instead of emitting schema-invalid negative
    offsets."""
    p = CommProfiler()
    m = _comm_meta(p)
    p.events = [_ev(m, "issue", (0, 0), 1.0), _ev(m, "signal", (0, 0), 1.2)]
    t = RecordingTracker()
    t.epoch = 5.0  # epoch after every event
    assert emit_leg_spans(p, t) == 1
    (leg,) = t.records
    assert leg.t_start == 0.0
    assert validate_record(leg.to_dict()) == []
