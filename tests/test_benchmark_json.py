"""BENCH_*.json trajectory records (ROADMAP comm-model calibration data):
the run.py writer round-trips, and hybrid_sweep's structured records pair
every swept config with its comm-model prediction breakdown."""
import json

from benchmarks import hybrid_sweep
from benchmarks.run import parse_row, write_bench_json


def test_parse_row_keeps_commas_in_derived():
    r = parse_row("hybrid_sweep/x/N2/cfg_pp2,123.45,cfg=2,pp=2,speedup=3.9x")
    assert r == {"name": "hybrid_sweep/x/N2/cfg_pp2", "us": 123.45,
                 "derived": "cfg=2,pp=2,speedup=3.9x"}
    assert parse_row("broken,NaN,ERROR:x")["us"] is None


def test_hybrid_sweep_records_structure():
    recs = hybrid_sweep.records()
    rows = hybrid_sweep.run()
    assert len(recs) == len(rows)
    names = {r["name"] for r in recs}
    assert len(names) == len(recs)  # per-config, no duplicates
    for r in recs:
        assert r["predicted_step_us"] > 0
        assert set(r["workload"]) == {"batch", "seq", "heads", "head_dim",
                                      "n_layers"}
        assert set(r["plan"]) == {"cfg", "pp", "p_ulysses", "p_ring"}
        assert r["measured_step_us"] is None  # CPU container: fit target only
        assert "t_layer" in r["predicted_breakdown"] or (
            "t_layers" in r["predicted_breakdown"])
    # row <-> record latencies agree (the CSV is a projection of the JSON)
    by_name = {parse_row(row)["name"]: parse_row(row)["us"] for row in rows}
    for r in recs:
        assert abs(by_name[r["name"]] - r["predicted_step_us"]) < 0.01


def test_write_bench_json_roundtrip(tmp_path):
    rows = hybrid_sweep.run()[:3]
    path = write_bench_json(tmp_path, "hybrid_sweep", rows,
                            hybrid_sweep.records()[:3])
    data = json.loads(path.read_text())
    assert path.name == "BENCH_hybrid_sweep.json"
    assert data["schema"] == "bench.v1"
    assert len(data["rows"]) == 3 and len(data["records"]) == 3
    assert data["rows"][0]["name"].startswith("hybrid_sweep/")
