"""flux-12b [dit] — the paper's image-generation workload (§5.1)
[Flux.1, arXiv:2506.15742 / Black Forest Labs 2025].

Approximation (documented): Flux interleaves 19 double-stream and 38
single-stream MM-DiT blocks; we model it as a uniform stack of 96 adaLN
DiT blocks at the same width (d=3072, 24 heads × head_dim 128 — the head
geometry the paper's §5.3 sweeps use), giving ~11B parameters.  Latent
tokens arrive pre-patchified (VAE + patchify stubbed per DESIGN.md §6);
conditioning is a precomputed text-embedding sequence + timestep.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="flux-12b",
    family="dit",
    n_layers=96,
    d_model=3072,
    n_heads=24,
    n_kv_heads=24,
    head_dim=128,
    d_ff=12288,
    vocab=0,  # continuous latents, no token embedding
    rope="rope",
    causal=False,
    act="gelu",
    norm="layernorm",
    citation="Flux.1 [8]",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256
    )
