"""Collective toolkit for SwiftFusion's SP schedules on TPU meshes.

The paper implements its communication with one-sided NVSHMEM put/get so
that (a) no per-transfer sender/receiver rendezvous happens and (b) no SM
cycles are burnt on communication kernels.  The TPU-idiomatic equivalent
lives in ``repro.comm`` (DESIGN.md §8): channels whose ``put`` is a
``lax.ppermute`` — lowered to ``collective-permute-start/done`` pairs
executed by the ICI DMA engines (no core cycles), with XLA's latency-hiding
scheduler hoisting the ``start`` above independent compute — precisely the
overlap NVSHMEM gives the paper.  Every schedule is therefore built from
channel puts over a *flattened* SP axis, with the paper's logical
(P_u × P_r) factorisation expressed as plain rank arithmetic.  This module
owns the layout bookkeeping (GroupLayout) and the all-to-all entry points;
the staged transfer programs themselves are ``repro.comm.stream``'s.

Logical layout (see planner.py):
  flat rank p in [0, P_u * P_r) over the mesh SP axes (major axis first).
  SwiftFusion (ulysses_outer=True):  u = p // P_r,  r = p %  P_r
      → Ulysses groups span the slow outer (pod) boundary, Ring groups are
        contiguous inside a pod.
  USP       (ulysses_outer=False):   u = p %  P_u,  r = p // P_u
      → Ring groups span pods, Ulysses groups stay inside a pod.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from ..comm import staged_all_to_all, staged_ungroup

AxisNames = tuple[str, ...]


def flat_axis_size(mesh: jax.sharding.Mesh | None, axes: AxisNames) -> int:
    if mesh is None:  # inside shard_map: use psum-of-ones trick? callers pass mesh
        raise ValueError("mesh required")
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


def flat_rank(axes: AxisNames) -> jax.Array:
    """Flattened rank over (possibly multiple) named mesh axes, major-first."""
    return lax.axis_index(axes)


@dataclasses.dataclass(frozen=True)
class GroupLayout:
    """(P_u × P_r) logical factorisation of a flattened SP axis."""

    axes: AxisNames
    p_ulysses: int
    p_ring: int
    ulysses_outer: bool  # True = SwiftFusion/TAS; False = USP

    @property
    def size(self) -> int:
        return self.p_ulysses * self.p_ring

    # -- static (python int) coordinates, used to build perm tables --------
    def coords(self, p: int) -> tuple[int, int]:
        if self.ulysses_outer:
            return p // self.p_ring, p % self.p_ring
        return p % self.p_ulysses, p // self.p_ulysses

    def rank(self, u: int, r: int) -> int:
        if self.ulysses_outer:
            return u * self.p_ring + r
        return r * self.p_ulysses + u

    # -- traced coordinates, used inside shard_map bodies -------------------
    def my_coords(self) -> tuple[jax.Array, jax.Array]:
        p = flat_rank(self.axes)
        if self.ulysses_outer:
            return p // self.p_ring, p % self.p_ring
        return p % self.p_ulysses, p // self.p_ulysses

    # -- permutation tables --------------------------------------------------
    def ring_perm(self, shift: int = 1) -> list[tuple[int, int]]:
        """Rotate by ``shift`` inside each Ring group (same u)."""
        out = []
        for u in range(self.p_ulysses):
            for r in range(self.p_ring):
                out.append((self.rank(u, r), self.rank(u, (r + shift) % self.p_ring)))
        return out

    def ulysses_stage_perm(self, k: int) -> list[tuple[int, int]]:
        """Stage ``k`` of the decomposed all-to-all: u sends to (u + k) % P_u
        inside each Ulysses group (same r).  §4.3 'Breakdown of All-to-All'."""
        out = []
        for u in range(self.p_ulysses):
            for r in range(self.p_ring):
                out.append(
                    (self.rank(u, r), self.rank((u + k) % self.p_ulysses, r))
                )
        return out

    def seq_offset_of_rank(self, shard_len: int) -> jax.Array:
        """Global sequence offset of *this* device's original shard."""
        return flat_rank(self.axes) * shard_len

    def ulysses_group_offsets(self, shard_len: int) -> jax.Array:
        """Global seq offsets of the shards gathered from my Ulysses group,
        ordered by source ulysses-coordinate u' = 0..P_u-1.  Traced."""
        _, r = self.my_coords()
        us = jnp.arange(self.p_ulysses)
        if self.ulysses_outer:
            ranks = us * self.p_ring + r
        else:
            ranks = r * self.p_ulysses + us
        return ranks * shard_len


# ---------------------------------------------------------------------------
# Grouped all-to-all via staged channel puts (the one-sided decomposition);
# the transfer programs live in repro.comm.stream, this is the core-facing
# entry point.
# ---------------------------------------------------------------------------

def grouped_all_to_all(
    x: jax.Array,
    layout: GroupLayout,
    *,
    split_axis: int,
    stack_axis: int = 0,
    backend: str = "xla",
    interpret: bool = True,
) -> jax.Array:
    """All-to-all restricted to Ulysses groups of ``layout``.

    Splits ``x`` into P_u equal chunks along ``split_axis``; chunk j is
    delivered to ulysses-peer j.  Returns the received chunks stacked on a
    new leading axis ordered by *source* ulysses coordinate:
    ``out[j] = chunk (destined for me) from peer with u = j``.

    Implemented as P_u - 1 one-sided channel stages (comm.stream).  The
    diagonal chunk (j == my u) is **stationary** — the paper's §4.3
    observation — and never moves.
    """
    return staged_all_to_all(x, layout, split_axis=split_axis,
                             backend=backend, interpret=interpret)


def monolithic_all_to_all(
    x: jax.Array, layout: GroupLayout, *, split_axis: int,
    backend: str = "xla", interpret: bool = True,
) -> jax.Array:
    """Baseline atomic all-to-all (what Ulysses does before Torus).

    Same contract as :func:`grouped_all_to_all`.  Uses ``lax.all_to_all``
    when the ulysses group covers the whole flattened SP axis; otherwise
    falls back to the staged implementation (XLA's all_to_all has no
    subgroup support over a partial logical factor of a named axis).
    """
    if (layout.p_ring == 1 and layout.p_ulysses == layout.size
            and backend == "xla"):
        chunks = jnp.stack(jnp.split(x, layout.p_ulysses, axis=split_axis), axis=0)
        # tiled all-to-all over the leading [P_u] axis: slice j -> peer j,
        # received slices re-stacked in source order — one atomic XLA op.
        return lax.all_to_all(
            chunks, layout.axes, split_axis=0, concat_axis=0, tiled=True
        )
    return grouped_all_to_all(x, layout, split_axis=split_axis,
                              backend=backend, interpret=interpret)


def ungroup_all_to_all(
    stacked: jax.Array, layout: GroupLayout, *, concat_axis: int,
    backend: str = "xla", interpret: bool = True,
) -> jax.Array:
    """Inverse transform: send ``stacked[j]`` back to ulysses-peer j and
    concatenate the received chunks along ``concat_axis`` (the fourth
    all-to-all of Ulysses attention, applied to O)."""
    p_u = layout.p_ulysses
    if p_u == 1:
        return jnp.squeeze(stacked, axis=0)
    if (layout.p_ring == 1 and layout.p_ulysses == layout.size
            and backend == "xla"):
        moved = lax.all_to_all(
            stacked, layout.axes, split_axis=0, concat_axis=0, tiled=True
        )
        return jnp.concatenate(list(moved), axis=concat_axis)
    return staged_ungroup(stacked, layout, concat_axis=concat_axis,
                          backend=backend, interpret=interpret)
