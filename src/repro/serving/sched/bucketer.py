"""Resolution bucketing for the DiT request scheduler (DESIGN.md §9).

Requests are grouped by latent sequence length into per-bucket FIFO
queues.  SP requires a uniform sequence per batch, so a batch NEVER mixes
buckets — bucketing removes cross-resolution padding entirely; the only
padding left is the data-parallel divisibility pad (whole rows), which the
bucketer accounts per admission so the admission policy can trade it off
against deadline slack.

The aging helpers here are shared with ``ARServer`` slot admission: an
aged priority grows linearly with queue age, so any waiting request's
effective priority eventually exceeds every fixed base priority — that is
the starvation bound both engines rely on.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable


def aged_priority(base: float, age: float, rate: float) -> float:
    """Effective priority of a request that has waited ``age`` units.

    Monotone in age: with ``rate`` > 0 a request of base priority ``p``
    overtakes base priority ``q`` after ``(q - p) / rate`` units — the
    anti-starvation guarantee.
    """
    return base + age * rate


def padded_rows(k: int, dp: int) -> int:
    """Rows of data-parallel padding a batch of ``k`` real requests needs
    (SPMD batch sharding requires divisibility by the dp degree)."""
    if dp <= 1:
        return 0
    return -(-k // dp) * dp - k


def deadline_of(req) -> float | None:
    """Absolute deadline of a request carrying a relative ``sla`` (seconds
    from submission); None = best-effort."""
    sla = getattr(req, "sla", None)
    if sla is None:
        return None
    return req.submitted + sla


@dataclasses.dataclass
class BucketStats:
    batches: int = 0
    admitted: int = 0
    padded_rows: int = 0
    padded_token_work: int = 0  # padded rows x latent tokens each
    real_token_work: int = 0
    max_wait: float = 0.0  # worst queue age observed at admission


class Bucket:
    """FIFO queue of same-latent-length requests plus its accounting."""

    def __init__(self, seq_len: int):
        self.seq_len = seq_len
        self.q: deque = deque()
        self.stats = BucketStats()

    def __len__(self) -> int:
        return len(self.q)

    def oldest_age(self, now: float) -> float:
        if not self.q:
            return 0.0
        return max(0.0, now - self.q[0].submitted)

    def min_slack(self, now: float, batch_latency: float, k: int,
                  default: float) -> float:
        """Tightest (deadline - now - predicted latency) among the ``k``
        oldest requests; requests without an SLA contribute ``default``."""
        slack = default
        for i, r in enumerate(self.q):
            if i >= k:
                break
            d = deadline_of(r)
            if d is not None:
                slack = min(slack, d - now - batch_latency)
        return slack

    def push_front(self, reqs: list, pad_rows: int = 0) -> None:
        """Return preempted requests to the head of the queue in their
        original order, WITHOUT touching ``submitted`` — a parked request
        keeps its accrued starvation age (preemption invariant (a),
        tests/test_sched_control.py).

        The admission accounting ``pop`` recorded is reversed (the batch
        did not complete; its eventual re-admission re-accounts it), so
        ``BucketStats`` never double-counts a parked batch.  ``max_wait``
        is deliberately NOT reversed: the wait observed at the first
        admission really happened."""
        for r in reversed(reqs):
            self.q.appendleft(r)
        st = self.stats
        st.batches -= 1
        st.admitted -= len(reqs)
        st.padded_rows -= pad_rows
        st.padded_token_work -= pad_rows * self.seq_len
        st.real_token_work -= len(reqs) * self.seq_len

    def pop(self, k: int, now: float, dp: int) -> list:
        """Admit the ``k`` oldest requests and account the padding the
        admission implies."""
        assert 0 < k <= len(self.q), (k, len(self.q))
        out = [self.q.popleft() for _ in range(k)]
        pad = padded_rows(k, dp)
        st = self.stats
        st.batches += 1
        st.admitted += k
        st.padded_rows += pad
        st.padded_token_work += pad * self.seq_len
        st.real_token_work += k * self.seq_len
        st.max_wait = max(st.max_wait,
                          max(now - r.submitted for r in out))
        return out


class Bucketer:
    """Per-latent-length bucket queues with padding/starvation accounting."""

    def __init__(self):
        self.buckets: dict[int, Bucket] = {}

    def add(self, req) -> None:
        b = self.buckets.get(req.seq_len)
        if b is None:
            b = self.buckets[req.seq_len] = Bucket(req.seq_len)
        b.q.append(req)

    def requeue(self, reqs: list, pad_rows: int = 0) -> None:
        """Re-enqueue a preempted batch at the front of its bucket,
        oldest first, with accrued ages intact and its admission
        accounting reversed.

        Batches NEVER mix buckets (SP needs one latent length per batch),
        so a preempted batch's requests all share one seq_len — that
        invariant is asserted here rather than papered over: the old code
        silently zeroed ``pad_rows`` for a multi-bucket list, which would
        mis-account padding with no signal if the invariant ever broke."""
        if not reqs:
            assert pad_rows == 0, (
                f"requeue of an empty batch cannot carry {pad_rows} pad rows")
            return
        by_seq: dict[int, list] = {}
        for r in reqs:
            by_seq.setdefault(r.seq_len, []).append(r)
        assert len(by_seq) == 1, (
            f"requeued batch mixes buckets {sorted(by_seq)}: batches never "
            f"mix buckets, so a preempted batch must be single-bucket")
        ((seq, rs),) = by_seq.items()
        b = self.buckets.get(seq)
        if b is None:
            b = self.buckets[seq] = Bucket(seq)
        b.push_front(rs, pad_rows)

    def drain(self) -> list:
        """Evacuate every queued (not-yet-admitted) request — what a
        failed fleet replica hands back to the router for re-dispatch
        (serving/fleet.py).  Global FIFO by submission time, ``submitted``
        untouched (accrued age survives the failover, same invariant as
        ``requeue``).  Admission accounting is NOT reversed: queued
        requests were never admitted, so there is nothing to reverse."""
        out: list = []
        for b in self.buckets.values():
            out.extend(b.q)
            b.q.clear()
        out.sort(key=lambda r: r.submitted)
        return out

    @property
    def pending(self) -> int:
        return sum(len(b) for b in self.buckets.values())

    def nonempty(self) -> Iterable[Bucket]:
        # deterministic order: insertion order of first appearance
        return [b for b in self.buckets.values() if len(b)]

    # -- aggregated accounting -------------------------------------------
    def totals(self) -> BucketStats:
        t = BucketStats()
        for b in self.buckets.values():
            s = b.stats
            t.batches += s.batches
            t.admitted += s.admitted
            t.padded_rows += s.padded_rows
            t.padded_token_work += s.padded_token_work
            t.real_token_work += s.real_token_work
            t.max_wait = max(t.max_wait, s.max_wait)
        return t
