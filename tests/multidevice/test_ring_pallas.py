"""Pallas-backend ring/torus attention vs the single-device reference on
the 8-device CPU mesh (interpret mode) — the acceptance gate for the
fused comm path (DESIGN.md §8.1).

Covers the carried (O', l, m) merge across ring steps (P_r > 1 circulates
the kernel state), GQA head grouping, causal/window masks, both torus
strategies (swift_torus per-stage RINGATTN and the usp-like monolithic
gather), and xla-vs-pallas parity of the full sp_attention outputs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import comm
from repro.compat import shard_map
from repro.core import MaskSpec, SPConfig, reference_attention, sp_attention
from repro.core.collectives import GroupLayout
from repro.core.ring import ring_attention
from repro.core.softmax import attend_partial, finalize

TOL = dict(rtol=1e-5, atol=1e-5)


def _mk(seed, b, l, hq, hkv, d, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, l, hq, d), dtype),
            jax.random.normal(ks[1], (b, l, hkv, d), dtype),
            jax.random.normal(ks[2], (b, l, hkv, d), dtype))


def _ring_mesh():
    return jax.make_mesh((4, 2), ("sp", "data"))


def _run_ring(mesh, layout, q, k, v, *, backend, causal=False, window=None,
              extra_chunk=None):
    """ring_attention under shard_map; optionally merge an accum partial
    computed from an extra resident KV chunk (the carried-state path)."""
    ls = q.shape[1] // 4

    def body(q, k, v, ek=None, ev=None):
        qs = q.shape[1]
        qp = layout.seq_offset_of_rank(qs) + jnp.arange(qs)
        kpfn = lambda r: r * ls + jnp.arange(ls)
        accum = None
        if ek is not None:
            e_off = extra_chunk[2]
            accum = attend_partial(
                q, ek, ev,
                mask=MaskSpec(causal=causal, window=window, q_pos=qp,
                              k_pos=e_off + jnp.arange(ek.shape[1])))
        part = ring_attention(
            q, k, v, layout, q_pos=qp, k_pos_fn=kpfn, causal=causal,
            window=window, accum=accum, unroll=True, backend=backend,
            interpret=True)
        return finalize(part, dtype=q.dtype)

    spec = P(("data",), ("sp",), None, None)
    espec = P(("data",), None, None, None)
    if extra_chunk is not None:
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(spec, spec, spec, espec, espec), out_specs=spec,
            check_vma=False)
        return jax.jit(fn)(q, k, v, extra_chunk[0], extra_chunk[1])
    fn = shard_map(body, mesh=mesh, in_specs=(spec,) * 3, out_specs=spec,
                   check_vma=False)
    return jax.jit(fn)(q, k, v)


@pytest.mark.parametrize("causal,window", [(False, None), (True, None),
                                           (True, 24)])
def test_ring_pallas_matches_reference(causal, window):
    mesh = _ring_mesh()
    layout = GroupLayout(("sp",), 1, 4, ulysses_outer=True)
    q, k, v = _mk(0, 2, 64, 2, 2, 16)
    out = _run_ring(mesh, layout, q, k, v, backend="pallas", causal=causal,
                    window=window)
    ref = reference_attention(q, k, v,
                              mask=MaskSpec(causal=causal, window=window))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_ring_pallas_gqa_grouping():
    """GQA: 4 q heads share 2 kv heads through the kernel's index_map."""
    mesh = _ring_mesh()
    layout = GroupLayout(("sp",), 1, 4, ulysses_outer=True)
    q, k, v = _mk(1, 2, 64, 4, 2, 16)
    out = _run_ring(mesh, layout, q, k, v, backend="pallas", causal=True)
    ref = reference_attention(q, k, v, mask=MaskSpec(causal=True))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_ring_pallas_carried_state_merge():
    """An accum Partial (extra KV chunk attended before the ring) must
    merge exactly with the kernel-carried (O', l, m) ring state."""
    mesh = _ring_mesh()
    layout = GroupLayout(("sp",), 1, 4, ulysses_outer=True)
    q, k, v = _mk(2, 2, 64, 2, 2, 16)
    eks = jax.random.split(jax.random.PRNGKey(9), 2)
    ek = jax.random.normal(eks[0], (2, 32, 2, 16))
    ev = jax.random.normal(eks[1], (2, 32, 2, 16))
    out = _run_ring(mesh, layout, q, k, v, backend="pallas", causal=True,
                    extra_chunk=(ek, ev, 64))
    # reference: attention over [k; ek] with ek positioned after the ring KV
    kk = jnp.concatenate([k, ek], axis=1)
    vv = jnp.concatenate([v, ev], axis=1)
    ref = reference_attention(q, kk, vv, mask=MaskSpec(causal=True))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_ring_backend_parity():
    mesh = _ring_mesh()
    layout = GroupLayout(("sp",), 1, 4, ulysses_outer=True)
    q, k, v = _mk(3, 2, 64, 2, 2, 16)
    a = _run_ring(mesh, layout, q, k, v, backend="xla", causal=True)
    b = _run_ring(mesh, layout, q, k, v, backend="pallas", causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)


# ---------------------------------------------------------------------------
# full sp_attention strategies with the pallas backend (mesh8: pod 2 x
# data 2 x model 2 -> P_u = P_r = 2 over the flattened SP axes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["swift_torus", "swift", "usp"])
@pytest.mark.parametrize("causal", [False, True])
def test_sp_attention_pallas_matches_reference(mesh8, strategy, causal):
    sp = SPConfig(strategy=strategy, sp_axes=("pod", "model"),
                  batch_axes=("data",), comm_backend="pallas",
                  kernel_interpret=True)
    q, k, v = _mk(4, 2, 32, 2, 2, 16)
    out = jax.jit(
        lambda q, k, v: sp_attention(q, k, v, mesh=mesh8, cfg=sp,
                                     causal=causal))(q, k, v)
    ref = reference_attention(q, k, v, mask=MaskSpec(causal=causal))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_sp_attention_gqa_pallas(mesh8):
    sp = SPConfig(strategy="swift_torus", sp_axes=("pod", "model"),
                  batch_axes=("data",), comm_backend="pallas",
                  kernel_interpret=True)
    q, k, v = _mk(5, 2, 32, 4, 2, 16)
    out = jax.jit(
        lambda q, k, v: sp_attention(q, k, v, mesh=mesh8, cfg=sp))(q, k, v)
    ref = reference_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_sp_attention_backend_parity_and_schedule(mesh8):
    base = SPConfig(strategy="swift_torus", sp_axes=("pod", "model"),
                    batch_axes=("data",))
    q, k, v = _mk(6, 2, 32, 2, 2, 16)
    outs = {}
    for backend in ("xla", "pallas"):
        cfg = dataclasses.replace(base, comm_backend=backend)
        with comm.record(backend) as tr:
            outs[backend] = jax.jit(
                lambda q, k, v, c=cfg: sp_attention(q, k, v, mesh=mesh8,
                                                    cfg=c))(q, k, v)
        if backend == "pallas":
            rep = comm.validate_semaphores(tr)
            assert rep.ok, rep.summary()
            assert rep.puts > 0
            assert all(e.backend == "pallas" for e in tr.events)
    np.testing.assert_allclose(np.asarray(outs["xla"]),
                               np.asarray(outs["pallas"]), **TOL)
