"""Assigned input shapes (assignment block) + the paper's own workloads.

Decode shapes lower ``serve_step`` (ONE new token against a KV cache of
``seq_len``); train/prefill lower ``train_step``/``prefill_step``.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Kind = Literal["training", "prefill", "decode"]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Kind


TRAIN_4K = InputShape("train_4k", 4_096, 256, "training")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES: dict[str, InputShape] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}

# Paper workloads (§5.1): DiT sampling is one prefill-like step per diffusion
# iteration over the full latent sequence.
#   Flux 3072x3072 image, patch 16x16 latents /8 VAE: (3072/8/2)^2 = 36864 tok
#   CogVideoX 20s 768x1360: ~48k visual tokens (paper's 96k-192k layerwise
#   sweep brackets these).
FLUX_3K = InputShape("flux_3072", 36_864, 1, "prefill")
FLUX_4K = InputShape("flux_4096", 65_536, 1, "prefill")
COGVIDEO_20S = InputShape("cogvideox_20s", 49_152, 1, "prefill")
COGVIDEO_40S = InputShape("cogvideox_40s", 98_304, 1, "prefill")

DIT_SHAPES = {
    s.name: s for s in (FLUX_3K, FLUX_4K, COGVIDEO_20S, COGVIDEO_40S)
}
