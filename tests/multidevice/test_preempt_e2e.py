"""Step-level preemption end-to-end (DESIGN.md §10, ISSUE 5) on the
8-fake-device hybrid mesh: a DiTServer runs a 256-bucket batch, an
overdue 1024-latent request is injected mid-batch through the engine's
``on_step`` hook, the preemption policy parks the 256 batch (requests
requeued with accrued age, KV state dropped), the SLA-critical request
is served, and the parked batch later completes — with latents
bitwise-equal to an unpreempted rerun of the same requests (initial
noise is drawn per request id, so trajectories are independent of batch
composition and admission order)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.core import PipelineConfig, SPConfig
from repro.launch.mesh import make_hybrid_mesh
from repro.serving import (
    ControlConfig,
    DiTRequest,
    DiTServer,
    PreemptionPolicy,
    SamplerConfig,
    SchedConfig,
)

# heavy e2e: two module-scoped server fixtures (preempted + rerun) each
# pay multi-second jit traces — runs in the dedicated CI 'slow' job, not
# the default tier-1 pass (RUN_SLOW_TESTS=1 to run locally)
pytestmark = pytest.mark.slow

# the injected request's SLA: comfortably below the remaining measured
# run time of the 256 batch (whose first step pays a multi-second jit
# trace on this mesh) and comfortably above its own predicted batch
# latency (~ms) — so the decision rule fires exactly once, for it
URGENT_SLA = 1.0


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_reduced("flux-12b"), dtype="float32")
    from repro.models import get_model

    bundle = get_model(cfg)
    params, axes = bundle.init(cfg, jax.random.PRNGKey(0), 1)
    mesh = make_hybrid_mesh(cfg=1, pipe=2, data=2, model=2)
    sp = SPConfig(strategy="swift_torus", sp_axes=("model",),
                  batch_axes=("data",), pp_axis="pipe")
    return cfg, params, axes, mesh, sp


def make_server(setup, control: ControlConfig) -> DiTServer:
    cfg, params, axes, mesh, sp = setup
    return DiTServer(
        params, cfg, mesh, sp,
        sampler=SamplerConfig(num_steps=3,
                              pipeline=PipelineConfig(pp=2, warmup_steps=1)),
        max_batch=2, param_axes=axes,
        # best-effort requests must never look preemption-critical on a
        # CPU mesh whose real steps dwarf the model's µs predictions
        sched=SchedConfig(max_batch=2, starvation_age=3600.0,
                          default_slack=1e9),
        control=control)


@pytest.fixture(scope="module")
def preempted(setup):
    """Preemptive run: two 256 requests admitted, the urgent 1024 request
    injected after the batch's first step."""
    # min_remaining_steps=1: with only 3 sampler steps every between-step
    # point must be a legal preemption point for the test's injection
    srv = make_server(setup, ControlConfig(
        preemption=PreemptionPolicy(min_remaining_steps=1)))
    srv.submit(DiTRequest(rid=0, seq_len=256))
    srv.submit(DiTRequest(rid=1, seq_len=256))
    injected = []

    def inject(server, step):
        if not injected:
            injected.append(step)
            server.submit(DiTRequest(rid=2, seq_len=1024, sla=URGENT_SLA))

    srv.on_step = inject
    results = srv.serve()
    srv.on_step = None
    return srv, results, injected


@pytest.fixture(scope="module")
def rerun(setup):
    """Unpreempted rerun of the same requests on a fresh server (no
    control loop): same rids, same buckets, no injection."""
    srv = make_server(setup, ControlConfig())
    for rid, n in ((0, 256), (1, 256), (2, 1024)):
        srv.submit(DiTRequest(rid=rid, seq_len=n,
                              sla=URGENT_SLA if n == 1024 else None))
    return srv, srv.serve()


def test_batch_parked_and_all_requests_complete(preempted):
    srv, results, injected = preempted
    assert injected == [0]  # hook fired once, after the first step
    assert srv.preemptions >= 1  # the 256 batch was parked
    assert srv.scheduler.preempted >= 2  # both its requests requeued
    assert sorted(r.rid for r in results) == [0, 1, 2]
    by_rid = {r.rid: r for r in results}
    for rid, n in ((0, 256), (1, 256), (2, 1024)):
        assert by_rid[rid].latents.shape == (n, 64)
        assert bool(jnp.all(jnp.isfinite(by_rid[rid].latents)))
    # the parked requests record their park; the urgent one ran clean
    assert by_rid[0].preemptions >= 1 and by_rid[1].preemptions >= 1
    assert by_rid[2].preemptions == 0


def test_parked_batch_restarts_with_full_trajectory(preempted):
    _, results, _ = preempted
    by_rid = {r.rid: r for r in results}
    for rid in (0, 1):
        # the completing run is a fresh 3-step trajectory (KV dropped at
        # the park), measured step-granularly by the control loop
        assert by_rid[rid].sampling_steps == 3
        assert len(by_rid[rid].kv_drift) == 3
        assert by_rid[rid].kv_drift[0] == 0.0  # restart re-warms
        assert len(by_rid[rid].step_times) == 3
        assert all(t > 0.0 for t in by_rid[rid].step_times)


def test_preempted_outputs_bitwise_equal_unpreempted_rerun(preempted, rerun):
    _, results, _ = preempted
    rerun_srv, rerun_results = rerun
    assert rerun_srv.preemptions == 0
    a = {r.rid: r.latents for r in results}
    b = {r.rid: r.latents for r in rerun_results}
    assert sorted(a) == sorted(b) == [0, 1, 2]
    for rid in (0, 1, 2):
        assert a[rid].dtype == b[rid].dtype
        assert bool(jnp.array_equal(a[rid], b[rid])), (
            f"rid {rid}: preempted-run latents differ from unpreempted "
            f"rerun (max abs diff "
            f"{float(jnp.max(jnp.abs(a[rid] - b[rid]))):.3e})")
